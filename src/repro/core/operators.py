"""Operators (paper Table II) adapted to the TPU hierarchy.

Every operator is a pure function ``MetadataSet -> MetadataSet`` with a
declared stage, parameter space (coarse grid for level-2 search, fine grid
for level-3 ML interpolation) and applicability rules (the paper's operator
dependencies, §IV-B).

GPU -> TPU operator mapping (DESIGN.md §2):

================  =====================  =======================================
paper (GPU)       here (TPU)             semantics
================  =====================  =======================================
COMPRESS          COMPRESS               drop zeros, canonicalise COO
SORT              SORT                   global row sort by desc length
SORT_SUB          SORT_SUB               per-branch row sort
BIN               BIN                    split rows into length bins (branches)
ROW_DIV           ROW_DIV                row stripes (branches)
COL_DIV           COL_DIV                column stripes (partial-sum branches)
BMTB_ROW_BLOCK    TILE_ROW_BLOCK         rows per Pallas grid tile
BMT_ROW_BLOCK     LANE_ROW_BLOCK         row-per-lane padded layout (ELL family)
BMT_NNZ_BLOCK     LANE_NNZ_BLOCK         nnz-balanced flat layout (merge/CSR5)
BMT(B)_PAD        LANE_PAD               pad tile widths to a multiple
SORT_BMTB         SORT_TILE              windowed sort (SELL-sigma analogue)
SET_RESOURCES     SET_RESOURCES          lanes/sublanes/backend knobs
THREAD_TOTAL_RED  LANE_TOTAL_RED         one row per lane, dense reduce
WARP_SEG_RED      SEG_SCAN_RED           in-tile segmented scan over nnz stream
WARP_BITMAP_RED   ONEHOT_MXU_RED         one-hot matmul reduce on the MXU
GMEM_ATOM_RED     GRID_ACC_RED combine   revisit output block across grid steps
SHMEM_OFFSET_RED  SCATTER_RED combine    segment-sum of tile partials
================  =====================  =======================================
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.design.registry import (OPERATOR_REGISTRY, Operator, OpSpec,
                                   STAGE_CONVERTING, STAGE_IMPLEMENTING,
                                   STAGE_MAPPING, get_operator,
                                   register_operator)
from .metadata import (Block, EllBucket, EllTileLayout, MetadataSet,
                       ReducePlan, SegTileLayout)

__all__ = ["OpSpec", "OPERATORS", "apply_op", "Operator",
           "STAGE_CONVERTING", "STAGE_MAPPING", "STAGE_IMPLEMENTING"]


# ``OpSpec`` and the ``Operator`` base class live in
# ``repro.design.registry`` (the open extension surface) and are
# re-exported here for the historical import path.

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m if m > 1 else max(x, 1)


def _resort_block_nnz(row_ids, rows, cols, vals, **kw) -> Block:
    order = np.lexsort((cols, rows))
    return Block(row_ids=row_ids.astype(np.int32), rows=rows[order].astype(np.int32),
                 cols=cols[order].astype(np.int32), vals=vals[order].astype(np.float32),
                 **kw)


def _permute_block_rows(block: Block, perm: np.ndarray) -> Block:
    """Reorder block rows by ``perm`` (new local r holds old local perm[r])."""
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.size, dtype=perm.dtype)
    return _resort_block_nnz(block.row_ids[perm], inv[block.rows].astype(np.int32),
                             block.cols, block.vals,
                             col_base=block.col_base, col_span=block.col_span,
                             tile_rows=block.tile_rows, pad_to=block.pad_to,
                             sort_tile=block.sort_tile)


def _split_block_rows(block: Block, boundaries: Sequence[int]) -> list[Block]:
    """Split a block into contiguous local-row ranges [b_i, b_{i+1})."""
    out = []
    row_ptr = np.concatenate([[0], np.cumsum(block.row_lengths())]).astype(np.int64)
    for lo, hi in zip(boundaries[:-1], boundaries[1:]):
        if hi <= lo:
            continue
        nlo, nhi = row_ptr[lo], row_ptr[hi]
        out.append(Block(row_ids=block.row_ids[lo:hi],
                         rows=(block.rows[nlo:nhi] - lo).astype(np.int32),
                         cols=block.cols[nlo:nhi], vals=block.vals[nlo:nhi],
                         col_base=block.col_base, col_span=block.col_span))
    return out


# ------------------------------ converting --------------------------------

@register_operator("COMPRESS")
class Compress(Operator):
    """Paper COMPRESS: ignore all zeros; canonicalise the COO stream."""

    name, stage = "COMPRESS", STAGE_CONVERTING

    @staticmethod
    def applicable(meta):
        return not meta.compressed

    @staticmethod
    def apply(meta, spec):
        blocks = []
        for b in meta.blocks:
            keep = b.vals != 0.0
            blocks.append(_resort_block_nnz(b.row_ids, b.rows[keep], b.cols[keep],
                                            b.vals[keep]))
        return dataclasses.replace(meta.with_blocks(blocks, spec.label()),
                                   compressed=True)


@register_operator("SORT")
class Sort(Operator):
    """Paper SORT: global decreasing row-length sort (JAD/SELL-sigma style)."""

    name, stage = "SORT", STAGE_CONVERTING

    @staticmethod
    def applicable(meta):
        return meta.compressed and len(meta.blocks) == 1

    @staticmethod
    def apply(meta, spec):
        b = meta.blocks[0]
        perm = np.argsort(-b.row_lengths(), kind="stable").astype(np.int32)
        return meta.with_blocks([_permute_block_rows(b, perm)], spec.label())


@register_operator("SORT_SUB")
class SortSub(Operator):
    """Paper SORT_SUB: sort rows by length within each branch.

    With a single branch (e.g. a degenerate BIN that produced one bin)
    this degenerates to SORT — still applicable."""

    name, stage = "SORT_SUB", STAGE_CONVERTING

    @staticmethod
    def applicable(meta):
        return meta.compressed

    @staticmethod
    def apply(meta, spec):
        blocks = []
        for b in meta.blocks:
            perm = np.argsort(-b.row_lengths(), kind="stable").astype(np.int32)
            blocks.append(_permute_block_rows(b, perm))
        return meta.with_blocks(blocks, spec.label())


@register_operator("BIN")
class Bin(Operator):
    """Paper BIN (ACSR-style): group rows into branches by length bins."""

    name, stage = "BIN", STAGE_CONVERTING
    divides = True

    @staticmethod
    def coarse_grid(meta=None):
        return [{"n_bins": 2}, {"n_bins": 4}]

    @staticmethod
    def fine_grid(meta=None):
        return [{"n_bins": k} for k in (2, 3, 4, 6, 8)]

    @staticmethod
    def applicable(meta):
        return meta.compressed and len(meta.blocks) == 1

    @staticmethod
    def apply(meta, spec):
        n_bins = int(spec.param("n_bins", 2))
        b = meta.blocks[0]
        lengths = b.row_lengths()
        # geometric (power-of-two) bin boundaries, ACSR-style
        logs = np.ceil(np.log2(np.maximum(lengths, 1))).astype(np.int64)
        edges = np.unique(np.quantile(logs, np.linspace(0, 1, n_bins + 1)[1:-1]))
        bin_of = np.searchsorted(edges, logs, side="left")
        blocks = []
        for k in np.unique(bin_of):
            sel = np.where(bin_of == k)[0].astype(np.int32)
            perm = sel  # keep original relative order within bin
            mask = np.isin(b.rows, sel)
            remap = np.full(b.n_block_rows, -1, np.int32)
            remap[sel] = np.arange(sel.size, dtype=np.int32)
            blocks.append(_resort_block_nnz(b.row_ids[perm],
                                            remap[b.rows[mask]],
                                            b.cols[mask], b.vals[mask]))
        return meta.with_blocks(blocks, spec.label())


@register_operator("ROW_DIV")
class RowDiv(Operator):
    """Paper ROW_DIV: stripe rows into branches.

    strategy='even_rows' | 'even_nnz' | 'len_mutation' — the last is the
    paper's DIV_IN_ROW_LEN_MUTATION parameter-discretisation strategy.
    """

    name, stage = "ROW_DIV", STAGE_CONVERTING
    divides = True

    @staticmethod
    def coarse_grid(meta=None):
        return [{"strategy": "even_nnz", "parts": 2},
                {"strategy": "len_mutation", "factor": 8}]

    @staticmethod
    def fine_grid(meta=None):
        out = [{"strategy": s, "parts": p}
               for s in ("even_rows", "even_nnz") for p in (2, 3, 4)]
        out += [{"strategy": "len_mutation", "factor": f} for f in (4, 8, 16)]
        return out

    @staticmethod
    def applicable(meta):
        return meta.compressed and len(meta.blocks) == 1

    @staticmethod
    def apply(meta, spec):
        b = meta.blocks[0]
        strategy = spec.param("strategy", "even_rows")
        n = b.n_block_rows
        if strategy == "even_rows":
            parts = int(spec.param("parts", 2))
            bounds = np.linspace(0, n, parts + 1).astype(np.int64)
        elif strategy == "even_nnz":
            parts = int(spec.param("parts", 2))
            row_ptr = np.concatenate([[0], np.cumsum(b.row_lengths())])
            targets = np.linspace(0, b.nnz, parts + 1)[1:-1]
            bounds = np.concatenate([[0], np.searchsorted(row_ptr, targets), [n]])
        else:  # len_mutation: split where row length jumps by >= factor
            factor = float(spec.param("factor", 8))
            lengths = np.maximum(b.row_lengths(), 1)
            ratio = np.maximum(lengths[1:], lengths[:-1]) / np.minimum(
                lengths[1:], lengths[:-1])
            cuts = np.where(ratio >= factor)[0] + 1
            # discretise: keep at most 7 cut points (largest mutations first)
            if cuts.size > 7:
                mags = ratio[cuts - 1]
                cuts = np.sort(cuts[np.argsort(-mags)[:7]])
            bounds = np.concatenate([[0], cuts, [n]])
        bounds = np.unique(bounds)
        return meta.with_blocks(_split_block_rows(b, bounds), spec.label())


@register_operator("HYB_SPLIT")
class HybSplit(Operator):
    """BEYOND-PAPER operator: HYB-style per-row decomposition.

    The paper's §VII-H names this its main limitation ("the matrix
    decomposition strategy of HYB ... has not been included", losing to
    HYB on GL7d19-like matrices). We add it to the operator set: split
    every row at position ``width`` — the first ``width`` non-zeros per
    row form a regular branch (ELL-friendly), the overflow forms an
    irregular branch (nnz-split-friendly). Branch outputs overlap in rows
    and sum via the scatter combine, so any per-branch design composes.

    width is quantile-parameterised (the paper's parameter-discretisation
    trick): width = ceil(quantile q of non-empty row lengths).
    """

    name, stage = "HYB_SPLIT", STAGE_CONVERTING
    divides = True

    @staticmethod
    def coarse_grid(meta=None):
        return [{"q": 0.5}, {"q": 0.9}]

    @staticmethod
    def fine_grid(meta=None):
        return [{"q": q} for q in (0.25, 0.5, 0.75, 0.9, 0.95)]

    @staticmethod
    def applicable(meta):
        return meta.compressed and len(meta.blocks) == 1

    @staticmethod
    def apply(meta, spec):
        q = float(spec.param("q", 0.75))
        b = meta.blocks[0]
        lengths = b.row_lengths()
        nonzero = lengths[lengths > 0]
        if nonzero.size == 0:
            return meta.with_blocks([b], spec.label())
        width = max(1, int(np.ceil(np.quantile(nonzero, q))))
        row_ptr = np.concatenate([[0], np.cumsum(lengths)]).astype(np.int64)
        pos = np.arange(b.nnz, dtype=np.int64) - row_ptr[b.rows]
        reg = pos < width
        blocks = [_resort_block_nnz(b.row_ids, b.rows[reg], b.cols[reg],
                                    b.vals[reg])]
        if (~reg).any():
            blocks.append(_resort_block_nnz(b.row_ids, b.rows[~reg],
                                            b.cols[~reg], b.vals[~reg]))
        return meta.with_blocks(blocks, spec.label())


@register_operator("COL_DIV")
class ColDiv(Operator):
    """Paper COL_DIV: stripe columns; branches produce partial sums of y."""

    name, stage = "COL_DIV", STAGE_CONVERTING
    divides = True

    @staticmethod
    def coarse_grid(meta=None):
        return [{"parts": 2}]

    @staticmethod
    def fine_grid(meta=None):
        return [{"parts": p} for p in (2, 3, 4)]

    @staticmethod
    def applicable(meta):
        return meta.compressed and len(meta.blocks) == 1

    @staticmethod
    def apply(meta, spec):
        parts = int(spec.param("parts", 2))
        b = meta.blocks[0]
        bounds = np.linspace(0, meta.n_cols, parts + 1).astype(np.int64)
        blocks = []
        for lo, hi in zip(bounds[:-1], bounds[1:]):
            mask = (b.cols >= lo) & (b.cols < hi)
            if not mask.any():
                continue
            blocks.append(_resort_block_nnz(
                b.row_ids, b.rows[mask], b.cols[mask], b.vals[mask],
                col_base=int(lo), col_span=int(hi - lo)))
        return meta.with_blocks(blocks, spec.label())


# ------------------------------- mapping ----------------------------------

@register_operator("TILE_ROW_BLOCK")
class TileRowBlock(Operator):
    """BMTB_ROW_BLOCK analogue: rows per Pallas grid tile."""

    name, stage = "TILE_ROW_BLOCK", STAGE_MAPPING
    before_layout = True

    @staticmethod
    def coarse_grid(meta=None):
        return [{"rows": r} for r in (8, 32, 128)]

    @staticmethod
    def fine_grid(meta=None):
        return [{"rows": r} for r in (8, 16, 24, 32, 48, 64, 96, 128, 192, 256)]

    @staticmethod
    def applicable(meta):
        return meta.compressed and all(b.layout is None for b in meta.blocks)

    @staticmethod
    def apply(meta, spec):
        rows = int(spec.param("rows", 8))
        return meta.with_blocks([b.replace(tile_rows=rows) for b in meta.blocks],
                                spec.label())


@register_operator("SORT_TILE")
class SortTile(Operator):
    """SORT_BMTB analogue: sort rows inside windows of `window` tiles
    (SELL-C-sigma's sigma). Requires TILE_ROW_BLOCK."""

    name, stage = "SORT_TILE", STAGE_MAPPING
    before_layout = True
    requires = ("TILE_ROW_BLOCK",)

    @staticmethod
    def coarse_grid(meta=None):
        return [{"window": 4}, {"window": 16}]

    @staticmethod
    def fine_grid(meta=None):
        return [{"window": w} for w in (2, 4, 8, 16, 32, 64)]

    @staticmethod
    def applicable(meta):
        return (meta.compressed
                and all(b.tile_rows is not None and b.layout is None
                        for b in meta.blocks))

    @staticmethod
    def apply(meta, spec):
        window = int(spec.param("window", 4))
        blocks = []
        for b in meta.blocks:
            span = max(b.tile_rows * window, 1)
            lengths = b.row_lengths()
            perm = np.arange(b.n_block_rows, dtype=np.int32)
            for lo in range(0, b.n_block_rows, span):
                hi = min(lo + span, b.n_block_rows)
                seg = np.argsort(-lengths[lo:hi], kind="stable")
                perm[lo:hi] = lo + seg
            blocks.append(_permute_block_rows(b, perm).replace(sort_tile=True))
        return meta.with_blocks(blocks, spec.label())


@register_operator("LANE_PAD")
class LanePad(Operator):
    """BMT(B)_PAD analogue: round tile widths up to a multiple (bucketing)."""

    name, stage = "LANE_PAD", STAGE_MAPPING
    before_layout = True

    @staticmethod
    def coarse_grid(meta=None):
        return [{"pad_to": 1}, {"pad_to": 8}]

    @staticmethod
    def fine_grid(meta=None):
        return [{"pad_to": p} for p in (1, 2, 4, 8, 16, 32)]

    @staticmethod
    def applicable(meta):
        return meta.compressed and all(b.layout is None for b in meta.blocks)

    @staticmethod
    def apply(meta, spec):
        pad_to = int(spec.param("pad_to", 8))
        return meta.with_blocks([b.replace(pad_to=pad_to) for b in meta.blocks],
                                spec.label())


def _build_ell_layout(b: Block) -> EllTileLayout:
    n = b.n_block_rows
    R = b.tile_rows or _ceil_to(max(n, 1), 8)
    n_tiles = max(1, math.ceil(n / R))
    lengths = b.row_lengths()
    lengths_pad = np.zeros(n_tiles * R, np.int64)
    lengths_pad[:n] = lengths
    w_per_tile = lengths_pad.reshape(n_tiles, R).max(axis=1)
    w_per_tile = np.maximum(_ceil_to(1, b.pad_to),
                            ((w_per_tile + b.pad_to - 1) // b.pad_to) * b.pad_to)
    w_per_tile = np.maximum(w_per_tile, 1)

    row_ptr = np.concatenate([[0], np.cumsum(lengths)]).astype(np.int64)
    pos_in_row = np.arange(b.nnz, dtype=np.int64) - row_ptr[b.rows]
    tile_of_row = np.arange(n, dtype=np.int64) // R
    row_in_tile = np.arange(n, dtype=np.int64) % R

    buckets = []
    for w in np.unique(w_per_tile):
        tiles = np.where(w_per_tile == w)[0]
        t_rank = np.full(n_tiles, -1, np.int64)
        t_rank[tiles] = np.arange(tiles.size)
        Tb = tiles.size
        vals = np.zeros((Tb, R, int(w)), np.float32)
        cols = np.zeros((Tb, R, int(w)), np.int32)
        rowmap = np.full((Tb, R), -1, np.int32)
        nz_tile = t_rank[tile_of_row[b.rows]]
        sel = nz_tile >= 0
        vals[nz_tile[sel], row_in_tile[b.rows[sel]], pos_in_row[sel]] = b.vals[sel]
        cols[nz_tile[sel], row_in_tile[b.rows[sel]], pos_in_row[sel]] = b.cols[sel]
        rows_here = np.where(t_rank[tile_of_row] >= 0)[0]
        rowmap[t_rank[tile_of_row[rows_here]], row_in_tile[rows_here]] = \
            b.row_ids[rows_here]
        buckets.append(EllBucket(int(w), vals, cols, rowmap))
    return EllTileLayout(tile_rows=R, buckets=tuple(buckets))


@register_operator("LANE_ROW_BLOCK")
class LaneRowBlock(Operator):
    """BMT_ROW_BLOCK analogue: one row per lane, padded tiles (ELL family)."""

    name, stage = "LANE_ROW_BLOCK", STAGE_MAPPING
    builds_layout = "ell"

    @staticmethod
    def applicable(meta):
        return meta.compressed and all(b.layout is None for b in meta.blocks)

    @staticmethod
    def apply(meta, spec):
        blocks = [b.replace(layout=_build_ell_layout(b)) for b in meta.blocks]
        return meta.with_blocks(blocks, spec.label())


def _build_seg_layout(b: Block, chunk: int, lanes: int) -> SegTileLayout:
    nnz = max(b.nnz, 1)
    lanes = max(1, min(lanes, chunk))
    chunk = _ceil_to(chunk, lanes)
    sub = chunk // lanes
    pad_nnz = _ceil_to(nnz, chunk)
    T = pad_nnz // chunk

    rows = np.zeros(pad_nnz, np.int64)
    cols = np.zeros(pad_nnz, np.int32)
    vals = np.zeros(pad_nnz, np.float32)
    if b.nnz:
        rows[: b.nnz] = b.rows
        cols[: b.nnz] = b.cols
        vals[: b.nnz] = b.vals
        rows[b.nnz:] = b.rows[-1]  # padded entries: val 0, last real row

    tile_id = np.arange(pad_nnz, dtype=np.int64) // chunk
    new_row = np.ones(pad_nnz, bool)
    new_row[1:] = rows[1:] != rows[:-1]
    new_row[::chunk] = True  # tile boundaries restart the segment numbering
    c = np.cumsum(new_row)
    local = (c - c[tile_id * chunk]).astype(np.int64)  # 0-based within tile
    seg_rows = _ceil_to(int(local.max()) + 1, 8)

    rowmap = np.full((T, seg_rows), -1, np.int32)
    starts = np.where(new_row)[0]
    rowmap[tile_id[starts], local[starts]] = b.row_ids[rows[starts]]

    # CSR5-style segment descriptor: exclusive end of each in-tile segment.
    # Segment m of tile t ends where segment m+1 starts (or at `chunk`).
    seg_end = np.full((T, seg_rows), chunk, np.int32)
    pos_in_tile = (starts - tile_id[starts] * chunk).astype(np.int32)
    nxt = np.empty(starts.size, np.int32)
    nxt[:-1] = np.where(tile_id[starts[1:]] == tile_id[starts[:-1]],
                        pos_in_tile[1:], chunk)
    nxt[-1:] = chunk
    seg_end[tile_id[starts], local[starts]] = nxt

    shape = (T, sub, lanes)
    return SegTileLayout(vals=vals.reshape(shape), cols=cols.reshape(shape),
                         local_row=local.astype(np.int32).reshape(shape),
                         rowmap=rowmap, seg_end=seg_end, seg_rows=seg_rows)


@register_operator("LANE_NNZ_BLOCK")
class LaneNnzBlock(Operator):
    """BMT_NNZ_BLOCK analogue: nnz-balanced flat stream (merge/CSR5 family)."""

    name, stage = "LANE_NNZ_BLOCK", STAGE_MAPPING
    builds_layout = "seg"

    @staticmethod
    def coarse_grid(meta=None):
        return [{"chunk": 512}, {"chunk": 2048}]

    @staticmethod
    def fine_grid(meta=None):
        return [{"chunk": c} for c in (128, 256, 512, 1024, 2048, 4096, 8192)]

    @staticmethod
    def applicable(meta):
        return meta.compressed and all(b.layout is None for b in meta.blocks)

    @staticmethod
    def apply(meta, spec):
        chunk = int(spec.param("chunk", 1024))
        lanes = int(spec.param("lanes", 128))
        blocks = [b.replace(layout=_build_seg_layout(b, chunk, lanes))
                  for b in meta.blocks]
        return meta.with_blocks(blocks, spec.label())


@register_operator("SET_RESOURCES")
class SetResources(Operator):
    """Runtime knobs: lanes, fused-kernel megatile width, storage dtype.

    ``tiles_per_step`` (format tiles per fused-kernel grid step) and
    ``dtype`` ("float32" | "bfloat16" vals storage, fp32 accumulate) are
    recorded on the MetadataSet and consumed by ``plan_format`` — the
    DesignSpace weaves SET_RESOURCES specs into candidate graphs when the
    SearchConfig enables non-default choices, so the search picks them
    per matrix like any other design decision.
    """

    name, stage = "SET_RESOURCES", STAGE_MAPPING

    @staticmethod
    def coarse_grid(meta=None):
        return [{"lanes": 128}]

    @staticmethod
    def fine_grid(meta=None):
        return [{"lanes": l} for l in (64, 128, 256)]

    @staticmethod
    def apply(meta, spec):
        out = meta.with_blocks(list(meta.blocks), spec.label())
        kw = {}
        k = spec.param("tiles_per_step")
        if k is not None:
            kw["tiles_per_step"] = max(int(k), 1)
        d = spec.param("dtype")
        if d is not None:
            kw["storage_dtype"] = str(d)
        return dataclasses.replace(out, **kw) if kw else out


# ----------------------------- implementing -------------------------------

def _set_reduce(meta: MetadataSet, spec: OpSpec, kind: str,
                need_layout: type) -> MetadataSet:
    combine = spec.param("combine", "scatter")
    blocks = []
    for b in meta.blocks:
        if not isinstance(b.layout, need_layout):
            raise ValueError(f"{spec.name} needs {need_layout.__name__}, "
                             f"block has {type(b.layout).__name__}")
        blocks.append(b.replace(reduce=ReducePlan(kind=kind, combine=combine)))
    return meta.with_blocks(blocks, spec.label())


@register_operator("LANE_TOTAL_RED")
class LaneTotalRed(Operator):
    """THREAD_TOTAL_RED analogue: each lane owns a full row; dense reduce."""

    name, stage = "LANE_TOTAL_RED", STAGE_IMPLEMENTING
    is_reducer = True
    accepts_layouts = ("ell",)

    @staticmethod
    def coarse_grid(meta=None):
        return [{"combine": "scatter"}, {"combine": "grid_acc"}]

    fine_grid = coarse_grid

    @staticmethod
    def applicable(meta):
        return all(isinstance(b.layout, EllTileLayout) for b in meta.blocks)

    @staticmethod
    def apply(meta, spec):
        return _set_reduce(meta, spec, "lane_total", EllTileLayout)


@register_operator("SEG_SCAN_RED")
class SegScanRed(Operator):
    """WARP_SEG_RED analogue: segmented scan over the in-tile nnz stream."""

    name, stage = "SEG_SCAN_RED", STAGE_IMPLEMENTING
    is_reducer = True
    accepts_layouts = ("seg",)

    @staticmethod
    def coarse_grid(meta=None):
        return [{"combine": "scatter"}]

    fine_grid = coarse_grid

    @staticmethod
    def applicable(meta):
        return all(isinstance(b.layout, SegTileLayout) for b in meta.blocks)

    @staticmethod
    def apply(meta, spec):
        return _set_reduce(meta, spec, "seg_scan", SegTileLayout)


@register_operator("ONEHOT_MXU_RED")
class OneHotMxuRed(Operator):
    """TPU-native reduction: products x one-hot(local_row) matmul on the MXU.

    Replaces the GPU bitmap/shuffle reductions (no TPU analogue exists for
    those — DESIGN.md D5); turns the irregular reduce into dense MXU work.
    """

    name, stage = "ONEHOT_MXU_RED", STAGE_IMPLEMENTING
    is_reducer = True
    accepts_layouts = ("seg",)

    @staticmethod
    def coarse_grid(meta=None):
        return [{"combine": "scatter"}]

    fine_grid = coarse_grid

    @staticmethod
    def applicable(meta):
        return all(isinstance(b.layout, SegTileLayout) for b in meta.blocks)

    @staticmethod
    def apply(meta, spec):
        return _set_reduce(meta, spec, "onehot_mxu", SegTileLayout)


@register_operator("GMEM_ATOM_RED")
class GmemAtomRed(Operator):
    """Paper GMEM_ATOM_RED: add every product directly into y.

    On GPU this is a global-memory atomicAdd per non-zero (row-grouped
    CSR's reduction). TPU has no atomics, so the data path is a single
    global scatter-add of the flat product stream — XLA lowers it to a
    deterministic sort-based combiner; the Pallas backend falls back to
    the in-tile scan + scatter (DESIGN.md §2, atomics row). Despite the
    name it is often the FASTEST reduction for nnz-balanced layouts on
    backends with good native scatter (e.g. XLA:CPU), which is exactly
    why the paper keeps it in the operator set."""

    name, stage = "GMEM_ATOM_RED", STAGE_IMPLEMENTING
    is_reducer = True
    accepts_layouts = ("seg",)

    @staticmethod
    def coarse_grid(meta=None):
        return [{"combine": "scatter"}]

    fine_grid = coarse_grid

    @staticmethod
    def applicable(meta):
        return all(isinstance(b.layout, SegTileLayout) for b in meta.blocks)

    @staticmethod
    def apply(meta, spec):
        return _set_reduce(meta, spec, "gmem_atom", SegTileLayout)


# ``OPERATORS`` *is* the process-wide registry (same dict object), so
# out-of-tree operators registered via ``repro.design.register_operator``
# are visible through this historical surface too.
OPERATORS: dict[str, type[Operator]] = OPERATOR_REGISTRY


def apply_op(meta: MetadataSet, spec: OpSpec) -> MetadataSet:
    return get_operator(spec.name).apply(meta, spec)
