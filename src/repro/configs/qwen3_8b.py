"""qwen3-8b [hf:Qwen/Qwen3-8B] — dense GQA with qk_norm."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=12288, vocab=151936, mlp_kind="swiglu", norm="rms",
    qk_norm=True, rope_theta=1_000_000.0,
    notes="qk RMSNorm per head before RoPE; GQA kv=8.",
)
