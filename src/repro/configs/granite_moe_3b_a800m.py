"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0 family] — 40 experts
top-8."""
from .base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=512, vocab=49155, mlp_kind="swiglu", norm="rms",
    tie_embeddings=True,
    moe=MoECfg(n_experts=40, top_k=8, n_shared=0, d_expert=512, every=1),
    notes="GQA kv=8; 40 routed experts top-8, d_expert=512.",
)
