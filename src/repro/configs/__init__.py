"""Assigned-architecture registry: ``get_config(arch_id)`` / ``--arch`` ids."""
from __future__ import annotations

from .base import ArchConfig, MoECfg, SSMCfg, ShapeCell, SHAPE_CELLS, cells_for  # noqa: F401

from . import (granite_3_2b, starcoder2_7b, llama3_405b, qwen3_8b,
               phi_3_vision_4_2b, jamba_v0_1_52b, mamba2_1_3b,
               deepseek_moe_16b, granite_moe_3b_a800m, musicgen_large)

_MODULES = (granite_3_2b, starcoder2_7b, llama3_405b, qwen3_8b,
            phi_3_vision_4_2b, jamba_v0_1_52b, mamba2_1_3b,
            deepseek_moe_16b, granite_moe_3b_a800m, musicgen_large)

REGISTRY: dict[str, ArchConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}

ARCH_IDS = tuple(REGISTRY)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[arch_id]
