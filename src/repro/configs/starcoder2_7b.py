"""starcoder2-7b [arXiv:2402.19173] — dense GQA, RoPE, GELU MLP, LayerNorm."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
    d_ff=18432, vocab=49152, mlp_kind="gelu", norm="layer",
    rope_theta=100_000.0,
    notes="GQA kv=4; standard (non-gated) MLP and LayerNorm per paper.",
)
