"""Architecture configuration schema + input-shape cells.

One ``ArchConfig`` per assigned architecture (exact public config) plus a
``reduced()`` smoke variant exercised on CPU. Full configs are only ever
lowered via ShapeDtypeStruct in the dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

__all__ = ["MoECfg", "SSMCfg", "ArchConfig", "ShapeCell", "SHAPE_CELLS",
           "cells_for"]


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_expert: int = 0          # expert FFN hidden size
    every: int = 1             # MoE layer every N layers (1 = all)
    impl: str = "onehot"       # 'onehot' (GShard dispatch) | 'sorted' (AlphaSparse-style)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    conv_width: int = 4
    chunk: int = 256           # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                # dense | vlm | hybrid | ssm | moe | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    mlp_kind: str = "swiglu"   # 'swiglu' | 'gelu'
    norm: str = "rms"          # 'rms' | 'layer'
    qk_norm: bool = False
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    # hybrid layer pattern, repeated to n_layers: 'A'=attention, 'M'=mamba
    pattern: Optional[tuple[str, ...]] = None
    window: Optional[int] = None        # sliding-window attention size
    n_prefix: int = 0                   # stubbed modality prefix tokens (vlm/audio)
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def layer_kinds(self) -> tuple[str, ...]:
        if self.pattern is None:
            return ("A",) * self.n_layers
        reps = math.ceil(self.n_layers / len(self.pattern))
        return (self.pattern * reps)[: self.n_layers]

    def is_attention_free(self) -> bool:
        return all(k == "M" for k in self.layer_kinds())

    def supports_long_context(self) -> bool:
        """long_500k needs sub-quadratic attention: SSM/hybrid(-windowed)."""
        return self.family in ("ssm", "hybrid")

    def n_params(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, L = self.d_model, self.n_layers
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        kinds = self.layer_kinds()
        moe_every = self.moe.every if self.moe else 1
        for i, kind in enumerate(kinds):
            if kind == "A":
                q = d * self.n_heads * self.hd
                kv = 2 * d * self.n_kv_heads * self.hd
                o = self.n_heads * self.hd * d
                total += q + kv + o
            else:  # mamba2 block
                s = self.ssm
                d_in = s.expand * d
                n_h = d_in // s.head_dim
                g_bc = 2 * s.d_state
                total += d * (2 * d_in + g_bc + n_h)   # in_proj
                total += d_in * d                       # out_proj
                total += (d_in + g_bc) * s.conv_width   # conv
                total += 2 * n_h                        # A, dt_bias
            if self.moe and (i % moe_every == moe_every - 1):
                e = self.moe
                n_mats = 3 if self.mlp_kind == "swiglu" else 2
                total += (e.n_experts + e.n_shared) * n_mats * d * e.d_expert
                total += d * e.n_experts               # router
            else:
                n_mats = 3 if self.mlp_kind == "swiglu" else 2
                total += n_mats * d * self.d_ff
            total += 2 * d                             # norms
        return total

    def active_params_per_token(self) -> int:
        """MoE-aware active parameter count (for MODEL_FLOPS = 6*N_active*D)."""
        if self.moe is None:
            return self.n_params()
        full = self.n_params()
        e = self.moe
        n_mats = 3 if self.mlp_kind == "swiglu" else 2
        moe_layers = len([i for i in range(self.n_layers)
                          if i % e.every == e.every - 1])
        routed_total = e.n_experts * n_mats * self.d_model * e.d_expert
        routed_active = e.top_k * n_mats * self.d_model * e.d_expert
        return full - moe_layers * (routed_total - routed_active)

    def reduced(self) -> "ArchConfig":
        """CI-scale config of the same family for CPU smoke tests."""
        pattern = self.pattern
        n_layers = 2 if pattern is None else len(self.pattern)
        moe = None
        if self.moe:
            moe = dataclasses.replace(self.moe, n_experts=4, top_k=2,
                                      n_shared=min(self.moe.n_shared, 1),
                                      d_expert=32)
        ssm = None
        if self.ssm:
            ssm = SSMCfg(d_state=16, expand=2, head_dim=16, conv_width=4,
                         chunk=16)
        return dataclasses.replace(
            self, n_layers=n_layers, d_model=64,
            n_heads=4, n_kv_heads=min(self.n_kv_heads, 2), head_dim=16,
            d_ff=128, vocab=256, moe=moe, ssm=ssm,
            window=min(self.window, 32) if self.window else None,
            n_prefix=min(self.n_prefix, 4))


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # 'train' | 'prefill' | 'decode'


SHAPE_CELLS: tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)


def cells_for(cfg: ArchConfig) -> list[ShapeCell]:
    """The shape cells an architecture runs (long_500k needs sub-quadratic
    attention -> skipped for pure full-attention archs, see DESIGN.md §5)."""
    cells = []
    for c in SHAPE_CELLS:
        if c.name == "long_500k" and not cfg.supports_long_context():
            continue
        cells.append(c)
    return cells
