"""deepseek-moe-16b [arXiv:2401.06066] — fine-grained MoE, 2 shared + 64
routed top-6."""
from .base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400, mlp_kind="swiglu", norm="rms",
    moe=MoECfg(n_experts=64, top_k=6, n_shared=2, d_expert=1408, every=1),
    notes="Fine-grained expert segmentation: 64 routed experts (top-6) + 2 "
          "always-on shared experts, d_expert=1408. Deviation: the public "
          "model keeps layer 0 dense; we apply MoE to all layers per the "
          "assignment config line.",
)
