"""musicgen-large [arXiv:2306.05284] — decoder-only over EnCodec tokens.

Backbone only; the EnCodec tokenizer/delay-pattern frontend is a STUB:
``input_specs()`` provides codec token ids plus precomputed conditioning
frame embeddings as a prefix.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, mlp_kind="gelu", norm="layer", vocab=2048,
    rope_theta=10_000.0, n_prefix=64,
    notes="Decoder over EnCodec codebook tokens (vocab 2048); 64 stubbed "
          "conditioning-embedding prefix tokens. long_500k skipped "
          "(full attention).",
)
