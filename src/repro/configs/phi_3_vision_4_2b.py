"""phi-3-vision-4.2b [hf:microsoft/Phi-3-vision-128k-instruct] — VLM.

Backbone only (phi3-mini); the CLIP vision frontend is a STUB:
``input_specs()`` provides precomputed patch embeddings as a prefix
(n_prefix tokens of d_model), per the assignment brief.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32064, mlp_kind="swiglu", norm="rms",
    rope_theta=10_000.0, n_prefix=576,
    notes="GQA kv=32 (full MHA); 576 stubbed CLIP patch-embedding prefix "
          "tokens (24x24 grid). long_500k skipped (full attention).",
)
