"""llama3-405b [arXiv:2407.21783] — dense GQA, 128k vocab."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
    d_ff=53248, vocab=128256, mlp_kind="swiglu", norm="rms",
    rope_theta=500_000.0,
    notes="GQA kv=8. long_500k skipped: pure full attention (DESIGN.md §5).",
)
