"""jamba-v0.1-52b [arXiv:2403.19887] — hybrid Mamba+attention 1:7, MoE.

Layer pattern per paper: blocks of 8 with 1 attention layer (index 4);
MoE replaces the MLP every 2 layers; 16 experts top-2.
"""
from .base import ArchConfig, MoECfg, SSMCfg

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=65536, mlp_kind="swiglu", norm="rms",
    moe=MoECfg(n_experts=16, top_k=2, n_shared=0, d_expert=14336, every=2),
    ssm=SSMCfg(d_state=16, expand=2, head_dim=64, conv_width=4, chunk=256),
    pattern=("M", "M", "M", "M", "A", "M", "M", "M"),
    window=4096,
    notes="Mamba:attention 1:7 interleave; attention layers use a 4096 "
          "sliding window at long context so long_500k RUNS (documented "
          "deviation: paper uses full attention at 256k, DESIGN.md §5).",
)
