"""mamba2-1.3b [arXiv:2405.21060] — attention-free SSM (SSD algorithm)."""
from .base import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,  # heads unused (attn-free)
    d_ff=0, vocab=50280, mlp_kind="swiglu", norm="rms",
    ssm=SSMCfg(d_state=128, expand=2, head_dim=64, conv_width=4, chunk=256),
    pattern=("M",),
    notes="Pure SSD blocks, no attention and no separate MLP (d_ff=0). "
          "long_500k RUNS (O(L) scan, O(1) decode state).",
)
