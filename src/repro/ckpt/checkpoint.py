"""Checkpointing with elastic resharding and async save.

Layout: ``<dir>/step_<N>/`` holding one ``.npy`` per pytree leaf (flat
key-path names) plus ``meta.json`` (step, mesh shape, leaf index). Leaves
are saved as *global* arrays (device-agnostic), so a restore may target a
different mesh — elastic scaling — by simply re-device_put-ing with the
new sharding (``restore(..., shardings=new_specs)``).

On a real multi-host cluster each host writes only the shards it owns
(addressable_shards) and restore re-assembles; the single-host container
exercises the same code path with fully-addressable arrays.

Saves run on a background thread (training is never blocked on IO); the
latest complete checkpoint is tracked with an atomic ``COMMITTED`` marker,
so a crash mid-write can never corrupt the restore point (fault-tolerance
contract used by ``ft/manager.py``).
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _flatten(v, f"{prefix}/{k}" if prefix else str(k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{prefix}/{i}")
    else:
        yield prefix, tree


def _unflatten_like(template, flat: dict, prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_like(v, flat, f"{prefix}/{k}" if prefix else str(k))
                for k, v in template.items()}
    if isinstance(template, (list, tuple)):
        return type(template)(_unflatten_like(v, flat, f"{prefix}/{i}")
                              for i, v in enumerate(template))
    return flat[prefix]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------ save ---------------------------------

    def save(self, step: int, state, blocking: bool = False) -> None:
        # snapshot to host memory synchronously (cheap), write async
        host = {k: np.asarray(v) for k, v in _flatten(state)}
        if self._thread is not None:
            self._thread.join()  # one in-flight save at a time
        self._thread = threading.Thread(
            target=self._write, args=(step, host), daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: dict) -> None:
        path = self.dir / f"step_{step:08d}"
        tmp = self.dir / f".tmp_step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        index = {}
        for i, (key, arr) in enumerate(host.items()):
            fname = f"leaf_{i:05d}.npy"
            np.save(tmp / fname, arr)
            index[key] = fname
        (tmp / "meta.json").write_text(json.dumps(
            {"step": step, "leaves": index}))
        (tmp / "COMMITTED").touch()
        if path.exists():
            shutil.rmtree(path)
        tmp.rename(path)
        self._gc()

    def _gc(self):
        done = sorted(p for p in self.dir.glob("step_*")
                      if (p / "COMMITTED").exists())
        for p in done[: -self.keep]:
            shutil.rmtree(p)

    # ----------------------------- restore --------------------------------

    def latest_step(self) -> Optional[int]:
        done = sorted(p for p in self.dir.glob("step_*")
                      if (p / "COMMITTED").exists())
        if not done:
            return None
        return int(done[-1].name.split("_")[1])

    def restore(self, step: int, template, shardings=None):
        """Load a checkpoint. ``shardings`` (optional pytree of
        jax.sharding.Sharding matching ``template``) enables *elastic*
        restore onto any mesh — the saved global arrays are simply
        re-placed under the new sharding."""
        path = self.dir / f"step_{step:08d}"
        meta = json.loads((path / "meta.json").read_text())
        flat = {k: np.load(path / fn) for k, fn in meta["leaves"].items()}
        tree = _unflatten_like(template, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree
