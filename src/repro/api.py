"""The one compile API: ``repro.compile(matrix, target) -> SpmvPlan``.

AlphaSparse's contract is "arbitrary sparse matrix in, performant
machine-designed format + kernel out" (paper §III). This module is that
contract as a single surface:

* :class:`Target` — where the program runs: backend ("jax" | "pallas"),
  interpret mode, an optional device mesh (sharded execution), partition
  mode/balance, decode batch size, dtype.
* :func:`compile` — matrix + Target (+ search budget) in, :class:`SpmvPlan`
  out. ``budget`` is a ``SearchConfig`` (or seconds); ``graph=`` skips the
  search and designs with a fixed Operator Graph.
* :class:`SpmvPlan` / :class:`ShardedSpmvPlan` — THE program artifact: a
  registered JAX pytree whose *leaves* are the packed format arrays (no
  jitted-closure constants) and whose static treedef is the winning
  Operator Graph + kernel spec + Target. Plans call (1-D SpMV / 2-D fused
  SpMM dispatch), ``save``/``load`` through npz (graph + arrays — the
  loaded plan is bit-identical, no graph replay needed), ``describe()``
  and ``cost_analysis()``.
* :class:`PlanStore` — a directory of saved plans keyed by
  (matrix fingerprint, budget, Target); supersedes ``ProgramCache``'s
  replay-only entries for serving restarts.

The historical entrypoints (``search``, ``build_spmv``,
``sparsify_linear*``) are thin deprecated shims over this module.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
import math
import os
import tempfile
import warnings
from pathlib import Path
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import OperatorGraph, run_graph
from repro.core.kernel_builder import build_kernel, build_program
from repro.core.matrices import SparseMatrix
from repro.core.search import (ProgramCache, SearchConfig, SearchResult,
                               _graph_from_jsonable, _graph_to_jsonable,
                               run_search)

__all__ = ["Target", "SpmvPlan", "ShardedSpmvPlan", "PlanStore", "PlanWatch",
           "PlanIntegrityError", "compile", "load_plan"]

# Version 2 adds bf16 storage (arrays saved as uint16 views under
# "bf16!"-marked keys). Plans without bf16 arrays are still written as
# version 1, so older readers keep loading everything they can actually
# restore and get the clean "format too new" error otherwise.
PLAN_FORMAT_VERSION = 2


class PlanIntegrityError(ValueError):
    """A saved plan's content checksum does not match its arrays.

    Distinct from a truncated file (which fails inside ``np.load``): the
    zip container is intact but the payload differs from what ``save``
    wrote — silent disk corruption, a partial copy, or tampering.
    ``PlanStore.get`` treats it like any other unusable entry (recompile);
    ``PlanStore.verify``/``repair`` surface and quarantine it."""


def _content_checksum(header: dict, arrays: dict) -> str:
    """sha256 over the header (checksum field excluded) and every array's
    (key, dtype, shape, bytes), in sorted key order."""
    h = hashlib.sha256()
    h.update(json.dumps({k: v for k, v in header.items()
                         if k != "checksum"}, sort_keys=True).encode())
    for k in sorted(arrays):
        a = np.ascontiguousarray(arrays[k])
        h.update(k.encode())
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def _atomic_savez(path, header: dict, arrays: dict) -> None:
    """Crash-safe plan write: checksum the content, write to a tempfile in
    the destination directory, fsync, then ``os.replace`` — readers (and
    ``PlanStore.watch`` pollers) only ever observe the old file or the
    complete new one, never a half-written npz.

    ``np.savez`` is handed an open file object (not a path) because the
    path form appends ".npz" when the suffix is missing, which would break
    the atomic rename."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    header = dict(header)
    header["checksum"] = _content_checksum(header, arrays)
    fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=path.name + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, __plan__=np.str_(json.dumps(header)), **arrays)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def _atomic_write_text(path, text: str) -> None:
    path = Path(path)
    fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=path.name + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


# --------------------------------- Target ----------------------------------

@dataclasses.dataclass(frozen=True)
class Target:
    """Where a compiled plan runs.

    ``backend="jax"`` is the pure-jnp program (CPU oracle / timing);
    ``"pallas"`` the TPU kernels (``interpret=True`` is the CPU stand-in
    for Mosaic). A non-None ``mesh`` compiles a sharded plan over
    ``axis_name`` with the given ``partition`` mode ("row" | "col") and
    boundary ``balance`` ("nnz" | "rows"). ``batch_size`` is the number of
    right-hand sides the plan is tuned for (B > 1 makes the search time
    candidates on the fused SpMM path). ``dtype`` is the activation AND
    preferred storage dtype: ``"bfloat16"`` feeds x as bf16 and lets the
    search choose bf16-stored vals (+ int16 cols where n_cols fits) per
    matrix — kernels always accumulate in float32, so outputs stay fp32.
    """

    backend: str = "jax"
    interpret: bool = True
    mesh: Optional[object] = None          # jax.sharding.Mesh
    axis_name: str = "data"
    partition: str = "row"
    balance: str = "nnz"
    batch_size: int = 1
    dtype: str = "float32"

    def __post_init__(self):
        if self.backend not in ("jax", "pallas"):
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.partition not in ("row", "col"):
            raise ValueError(f"unknown partition {self.partition!r}")
        if self.dtype not in ("float32", "bfloat16"):
            raise ValueError(f"unsupported dtype {self.dtype!r} "
                             "(float32 | bfloat16)")

    def spec_dict(self) -> dict:
        """JSON-able identity (mesh reduced to its axis shape)."""
        d = {f.name: getattr(self, f.name)
             for f in dataclasses.fields(self) if f.name != "mesh"}
        d["mesh"] = (None if self.mesh is None
                     else sorted((str(k), int(v))
                                 for k, v in dict(self.mesh.shape).items()))
        return d

    def key(self) -> str:
        blob = json.dumps(self.spec_dict(), sort_keys=True)
        return hashlib.sha1(blob.encode()).hexdigest()[:8]


def _x_dtype(target: Target):
    return jnp.bfloat16 if target.dtype == "bfloat16" else jnp.float32


# npz cannot serialize ml_dtypes extension dtypes (bfloat16 lands as a raw
# void field); bf16 arrays travel as uint16 views under a marked key and
# are view-cast back on load — a bit-identical round trip.
_BF16_PREFIX = "bf16!"


def _npz_arrays(prefix: str, arrays: dict) -> dict:
    out = {}
    for k, v in arrays.items():
        a = np.asarray(v)
        if a.dtype == np.dtype(jnp.bfloat16):
            out[f"{prefix}::{_BF16_PREFIX}{k}"] = a.view(np.uint16)
        else:
            out[f"{prefix}::{k}"] = a
    return out


def _format_version(npz_arrays: dict) -> int:
    """1 for plans any reader can restore; 2 when bf16 keys are present
    (older readers would mis-restore them, so the version gate fires)."""
    tag = f"::{_BF16_PREFIX}"
    return 2 if any(tag in k for k in npz_arrays) else 1


def _npz_restore(prefix: str, z) -> dict:
    tag = f"{prefix}::"
    out = {}
    for k in z.files:
        if not k.startswith(tag):
            continue
        name = k[len(tag):]
        a = z[k]
        if name.startswith(_BF16_PREFIX):
            name = name[len(_BF16_PREFIX):]
            a = a.view(np.dtype(jnp.bfloat16))
        out[name] = jnp.asarray(a)
    return out


# ------------------------------ dense plans ---------------------------------

@functools.lru_cache(maxsize=256)
def _dense_kernel(spec_json: str, backend: str, interpret: bool):
    spec = json.loads(spec_json)
    return jax.jit(build_kernel(spec, backend=backend, interpret=interpret))


@dataclasses.dataclass(eq=False)
class SpmvPlan:
    """A compiled (single-mesh-less) SpMV/SpMM program artifact.

    Pytree: leaves are the format arrays (``fmt``), everything else is
    static treedef — so a plan can be passed through ``jax.jit`` /
    ``shard_map`` boundaries, donated, or checkpointed like any other
    parameter pytree.
    """

    supports_batch = True

    fmt: dict                       # name -> array  (the pytree leaves)
    spec_json: str                  # kernel spec (kernel_builder schema)
    graph_json: Optional[str]       # winning OperatorGraph, if any
    target: Target
    search_gflops: Optional[float] = None
    # failure-reason counts from the search that produced this plan, as a
    # sorted tuple of (taxonomy bucket, count) pairs — serialized, so a
    # plan born from a crash-riddled search stays visible after the fact
    failure_counts: Optional[tuple] = None
    # monotonic lineage version, bumped by every in-place update() and
    # background re-search adoption (repro.dyn). Serialized in the plan
    # header so hot-swap admission can reject a stale re-published store
    # entry; deliberately NOT part of the pytree aux data — bumping it
    # must never retrace jitted callers
    plan_version: int = 0
    # ephemeral: the full SearchResult when this plan came from a live
    # search in this process (not serialized, not part of the pytree)
    search_result: Optional[SearchResult] = dataclasses.field(
        default=None, compare=False, repr=False)

    # -- geometry ----------------------------------------------------------
    @functools.cached_property
    def spec(self) -> dict:
        return json.loads(self.spec_json)

    @property
    def n_rows(self) -> int:
        return self.spec["n_rows"]

    @property
    def n_cols(self) -> int:
        return self.spec["n_cols"]

    @property
    def nnz(self) -> int:
        return self.spec["nnz"]

    @property
    def graph(self) -> Optional[OperatorGraph]:
        if self.graph_json is None:
            return None
        return _graph_from_jsonable(json.loads(self.graph_json))

    @property
    def stored_bytes(self) -> int:
        return sum(int(np.prod(a.shape)) * np.dtype(a.dtype).itemsize
                   for a in self.fmt.values())

    # -- execution ---------------------------------------------------------
    def __call__(self, x) -> jax.Array:
        """x: (n_cols,) -> (n_rows,), or (n_cols, B) -> (n_rows, B)."""
        x = jnp.asarray(x, _x_dtype(self.target))
        fn = _dense_kernel(self.spec_json, self.target.backend,
                           self.target.interpret)
        return fn(self.fmt, x)

    # -- dynamic sparsity --------------------------------------------------
    def update(self, delta) -> "SpmvPlan":
        """Patch-in-place dynamic-sparsity step (``repro.dyn``).

        Applies a :class:`repro.dyn.PatternDelta` to the packed format
        arrays — new leaves, same static treedef, no Operator Graph
        replay, no kernel rebuild, no jit retrace — and returns the
        patched plan with ``plan_version + 1``. Raises
        ``repro.dyn.CapacityError`` when the delta does not fit the
        format in place (escalate to ``repro.dyn.DynamicSparsityManager``
        or a fresh :func:`compile`). For streams of deltas, hold a
        ``repro.dyn.PlanPatcher`` instead: it keeps the capacity index
        across calls, making each update O(delta)."""
        from repro.dyn.update import update_plan
        return update_plan(self, delta)

    # -- reporting ---------------------------------------------------------
    def describe(self) -> str:
        spec = self.spec
        g = self.graph
        lines = [f"SpmvPlan {spec['n_rows']}x{spec['n_cols']} "
                 f"nnz={spec['nnz']} padded={spec['padded_nnz']} "
                 f"stored={self.stored_bytes}B",
                 f"  target: backend={self.target.backend} "
                 f"interpret={self.target.interpret} "
                 f"batch_size={self.target.batch_size} "
                 f"dtype={self.target.dtype}",
                 f"  graph: {g.label() if g else '(heuristic)'}"]
        if self.search_gflops is not None:
            lines.append(f"  searched: {self.search_gflops:.3f} GFLOPS")
        if self.failure_counts:
            buckets = ", ".join(f"{k}={v}" for k, v in self.failure_counts)
            lines.append(f"  search failures: {buckets}")
        for s in spec["steps"]:
            lines.append(f"  step {s['key']}: {s['report']}")
        from repro.dyn.capacity import capacity_lines
        lines.extend(capacity_lines(self))
        return "\n".join(lines)

    def cost_analysis(self, batch_size: Optional[int] = None) -> dict:
        """XLA cost analysis of the compiled call, shape-normalized
        across jax versions (``repro.launch.compat``)."""
        from repro.launch.compat import normalize_cost_analysis
        b = batch_size if batch_size is not None else self.target.batch_size
        shape = (self.n_cols,) if b <= 1 else (self.n_cols, b)
        x = jax.ShapeDtypeStruct(shape, _x_dtype(self.target))
        fn = _dense_kernel(self.spec_json, self.target.backend,
                           self.target.interpret)
        compiled = fn.lower(self.fmt, x).compile()
        out = normalize_cost_analysis(compiled.cost_analysis())
        # format capacity headroom (repro.dyn): how much pattern mutation
        # this plan can absorb in place before a re-search is needed
        from repro.dyn.capacity import capacity_report
        out["capacity"] = capacity_report(self)
        return out

    # -- serialization -----------------------------------------------------
    def save(self, path) -> None:
        arrays = _npz_arrays("fmt", self.fmt)
        header = {"format_version": _format_version(arrays), "kind": "dense",
                  "spec": self.spec, "graph": (None if self.graph_json is None
                                               else json.loads(self.graph_json)),
                  "target": self.target.spec_dict(),
                  "search_gflops": self.search_gflops,
                  "plan_version": int(self.plan_version),
                  "failure_counts": (None if self.failure_counts is None
                                     else [list(p)
                                           for p in self.failure_counts])}
        _atomic_savez(path, header, arrays)

    @staticmethod
    def load(path, mesh=None) -> "SpmvPlan | ShardedSpmvPlan":
        """Load any saved plan; sharded plans need ``mesh`` re-attached."""
        return load_plan(path, mesh=mesh)


def _target_from_dict(d: dict, mesh=None) -> Target:
    kw = {k: v for k, v in d.items() if k != "mesh"}
    return Target(mesh=mesh, **kw)


def _tree_flatten_plan(plan: SpmvPlan):
    keys = tuple(sorted(plan.fmt))
    leaves = tuple(plan.fmt[k] for k in keys)
    aux = (keys, plan.spec_json, plan.graph_json, plan.target,
           plan.search_gflops, plan.failure_counts)
    return leaves, aux


def _tree_unflatten_plan(aux, leaves) -> SpmvPlan:
    keys, spec_json, graph_json, target, gflops, failure_counts = aux
    return SpmvPlan(fmt=dict(zip(keys, leaves)), spec_json=spec_json,
                    graph_json=graph_json, target=target,
                    search_gflops=gflops, failure_counts=failure_counts)


jax.tree_util.register_pytree_node(SpmvPlan, _tree_flatten_plan,
                                   _tree_unflatten_plan)


# ------------------------------ sharded plans -------------------------------

@functools.lru_cache(maxsize=64)
def _sharded_fn(steps_json: str, mode: str, n_out: int, mesh, axis_name: str,
                backend: str, interpret: bool):
    from repro.dist.spmv import make_stacked_fn
    return make_stacked_fn(json.loads(steps_json), mode, n_out, mesh,
                           axis_name, backend=backend, interpret=interpret)


@dataclasses.dataclass(eq=False)
class ShardedSpmvPlan:
    """A compiled sharded plan: per-family stacked format arrays (leaves,
    leading dim sharded over the mesh axis) + static shard geometry.

    Unlike the old closure design, each device stores only its 1/n_shards
    slice of every family stack; the shard_map body receives the stacks as
    operands and needs no ``lax.switch``.
    """

    supports_batch = True

    stacks: dict                    # name -> (n_shards, ...) arrays (leaves)
    steps_json: str                 # synthetic per-family kernel spec
    mode: str                       # 'row' | 'col'
    n_rows: int
    n_cols: int
    nnz: int
    band_rows: int                  # row mode: padded per-device band size
    bounds: tuple                   # ((start, stop), ...) per shard
    target: Target
    replicated_bytes: int = 0       # closure-design baseline (all shards)
    # aggregated per-shard failure taxonomy (sorted (bucket, count) pairs);
    # a "fallback" entry counts shards substituted with the baseline
    failure_counts: Optional[tuple] = None
    search_result: Optional[object] = dataclasses.field(
        default=None, compare=False, repr=False)

    @property
    def n_shards(self) -> int:
        return len(self.bounds)

    @property
    def per_device_format_bytes(self) -> int:
        n = max(self.n_shards, 1)
        return sum(v.nbytes // n for v in self.stacks.values())

    @property
    def replicated_format_bytes(self) -> int:
        return self.replicated_bytes

    @classmethod
    def from_program(cls, sprog, target: Target,
                     search_result=None) -> "ShardedSpmvPlan":
        """Adopt a ``dist.spmv.ShardedSpmvProgram``'s stacked operands."""
        failure_counts = None
        if search_result is not None and getattr(search_result,
                                                 "failure_counts", None):
            failure_counts = tuple(
                sorted(search_result.failure_counts.items()))
        return cls(stacks=dict(sprog.stacks),
                   steps_json=json.dumps(sprog.steps),
                   mode=sprog.mode, n_rows=sprog.n_rows,
                   n_cols=sprog.n_cols, nnz=sprog.nnz,
                   band_rows=sprog.band_rows,
                   bounds=tuple((s.start, s.stop) for s in sprog.shards),
                   target=target,
                   replicated_bytes=sprog.replicated_format_bytes,
                   failure_counts=failure_counts,
                   search_result=search_result)

    def _n_out(self) -> int:
        return self.band_rows if self.mode == "row" else self.n_rows

    def __call__(self, x) -> jax.Array:
        if self.target.mesh is None:
            raise ValueError("sharded plan has no mesh attached; load with "
                             "SpmvPlan.load(path, mesh=...) or rebuild the "
                             "Target with a mesh")
        from repro.dist.spmv import stacked_call
        fn = _sharded_fn(self.steps_json, self.mode, self._n_out(),
                         self.target.mesh, self.target.axis_name,
                         self.target.backend, self.target.interpret)
        return stacked_call(fn, self.stacks, x, self.mode, self.n_cols,
                            [stop - start for start, stop in self.bounds],
                            dtype=_x_dtype(self.target))

    def update(self, delta):
        """Sharded plans do not support patch-in-place updates: a delta
        can move nnz across shard bounds, which changes the static shard
        geometry. Re-compile for the mutated matrix instead."""
        raise NotImplementedError(
            "ShardedSpmvPlan.update is not supported (a PatternDelta can "
            "cross shard bounds); re-run repro.compile on the mutated "
            "matrix")

    def describe(self) -> str:
        steps = json.loads(self.steps_json)
        lines = [f"ShardedSpmvPlan {self.n_rows}x{self.n_cols} "
                 f"nnz={self.nnz} mode={self.mode} "
                 f"shards={self.n_shards}",
                 f"  target: backend={self.target.backend} "
                 f"interpret={self.target.interpret} "
                 f"axis={self.target.axis_name}",
                 f"  format bytes/device: {self.per_device_format_bytes} "
                 f"(closure baseline {self.replicated_bytes})"]
        if self.failure_counts:
            buckets = ", ".join(f"{k}={v}" for k, v in self.failure_counts)
            lines.append(f"  shard-search failures: {buckets}")
        for s in steps:
            lines.append(f"  family {s['key']}: {s['report']}")
        return "\n".join(lines)

    def cost_analysis(self, batch_size: Optional[int] = None) -> dict:
        from repro.launch.compat import normalize_cost_analysis
        if self.target.mesh is None:
            raise ValueError("sharded plan has no mesh attached; load with "
                             "SpmvPlan.load(path, mesh=...) first")
        b = batch_size if batch_size is not None else self.target.batch_size
        n_in = (self.n_cols if self.mode == "row"
                else -(-self.n_cols // self.n_shards) * self.n_shards)
        shape = (n_in,) if b <= 1 else (n_in, b)
        x = jax.ShapeDtypeStruct(shape, _x_dtype(self.target))
        fn = _sharded_fn(self.steps_json, self.mode, self._n_out(),
                         self.target.mesh, self.target.axis_name,
                         self.target.backend, self.target.interpret)
        compiled = fn.lower(self.stacks, x).compile()
        return normalize_cost_analysis(compiled.cost_analysis())

    def save(self, path) -> None:
        arrays = _npz_arrays("stack", self.stacks)
        header = {"format_version": _format_version(arrays),
                  "kind": "sharded",
                  "steps": json.loads(self.steps_json), "mode": self.mode,
                  "n_rows": self.n_rows, "n_cols": self.n_cols,
                  "nnz": self.nnz, "band_rows": self.band_rows,
                  "bounds": [list(b) for b in self.bounds],
                  "replicated_bytes": self.replicated_bytes,
                  "failure_counts": (None if self.failure_counts is None
                                     else [[p[0], int(p[1])]
                                           for p in self.failure_counts]),
                  "target": self.target.spec_dict()}
        _atomic_savez(path, header, arrays)

    load = staticmethod(SpmvPlan.load)


def _tree_flatten_sharded(plan: ShardedSpmvPlan):
    keys = tuple(sorted(plan.stacks))
    leaves = tuple(plan.stacks[k] for k in keys)
    aux = (keys, plan.steps_json, plan.mode, plan.n_rows, plan.n_cols,
           plan.nnz, plan.band_rows, plan.bounds, plan.target,
           plan.replicated_bytes, plan.failure_counts)
    return leaves, aux


def _tree_unflatten_sharded(aux, leaves) -> ShardedSpmvPlan:
    (keys, steps_json, mode, n_rows, n_cols, nnz, band_rows, bounds,
     target, repl, failure_counts) = aux
    return ShardedSpmvPlan(stacks=dict(zip(keys, leaves)),
                           steps_json=steps_json, mode=mode, n_rows=n_rows,
                           n_cols=n_cols, nnz=nnz, band_rows=band_rows,
                           bounds=bounds, target=target,
                           replicated_bytes=repl,
                           failure_counts=failure_counts)


jax.tree_util.register_pytree_node(ShardedSpmvPlan, _tree_flatten_sharded,
                                   _tree_unflatten_sharded)


def load_plan(path, mesh=None) -> Union[SpmvPlan, ShardedSpmvPlan]:
    """Load a saved plan. Sharded plans need a live ``mesh`` re-attached
    (meshes name physical devices and are deliberately not serialized)."""
    with np.load(path, allow_pickle=False) as z:
        header = json.loads(str(z["__plan__"]))
        if header.get("format_version", 0) > PLAN_FORMAT_VERSION:
            raise ValueError(f"plan {path} has format_version "
                             f"{header['format_version']} > supported "
                             f"{PLAN_FORMAT_VERSION}")
        want = header.get("checksum")
        if want is not None:
            arrays = {k: z[k] for k in z.files if k != "__plan__"}
            got = _content_checksum(header, arrays)
            if got != want:
                raise PlanIntegrityError(
                    f"plan {path} failed its content checksum "
                    f"(stored {want[:12]}…, computed {got[:12]}…): the "
                    "file is corrupt or was modified after save")
        if header["kind"] == "dense":
            fmt = _npz_restore("fmt", z)
            fc = header.get("failure_counts")
            return SpmvPlan(
                fmt=fmt, spec_json=json.dumps(header["spec"]),
                graph_json=(None if header["graph"] is None
                            else json.dumps(header["graph"])),
                target=_target_from_dict(header["target"]),
                search_gflops=header.get("search_gflops"),
                failure_counts=(None if fc is None
                                else tuple((k, int(v)) for k, v in fc)),
                plan_version=int(header.get("plan_version", 0)))
        target = _target_from_dict(header["target"], mesh=mesh)
        stacks = _npz_restore("stack", z)
        if mesh is not None:
            n_saved = len(header["bounds"])
            n_mesh = dict(mesh.shape).get(target.axis_name)
            if n_mesh != n_saved:
                raise ValueError(
                    f"plan {path} was compiled for {n_saved} shards but the "
                    f"attached mesh has {n_mesh} devices on axis "
                    f"{target.axis_name!r}; re-compile for this mesh or "
                    "attach a matching one")
            from jax.sharding import NamedSharding, PartitionSpec as P
            sharding = NamedSharding(mesh, P(target.axis_name))
            stacks = {k: jax.device_put(v, sharding)
                      for k, v in stacks.items()}
        fc = header.get("failure_counts")
        return ShardedSpmvPlan(
            stacks=stacks, steps_json=json.dumps(header["steps"]),
            mode=header["mode"], n_rows=header["n_rows"],
            n_cols=header["n_cols"], nnz=header["nnz"],
            band_rows=header["band_rows"],
            bounds=tuple(tuple(b) for b in header["bounds"]),
            target=target, replicated_bytes=header["replicated_bytes"],
            failure_counts=(None if fc is None
                            else tuple((k, int(v)) for k, v in fc)))


# -------------------------------- compile -----------------------------------

def _as_search_config(budget, target: Target) -> SearchConfig:
    if budget is None:
        cfg = SearchConfig()
    elif isinstance(budget, SearchConfig):
        cfg = budget
    elif isinstance(budget, (int, float)):
        cfg = SearchConfig(max_seconds=float(budget))
    else:
        raise TypeError(f"budget must be a SearchConfig or seconds, got "
                        f"{type(budget).__name__}")
    bsz = target.batch_size if target.batch_size > 1 else cfg.batch_size
    cfg = dataclasses.replace(cfg, backend=target.backend,
                              batch_size=max(bsz, 1))
    # widen the SET_RESOURCES knob choices from the Target, but only when
    # the budget left them at None ("auto") — an explicit tuple, even the
    # single-default one, pins the knob and is respected as-is: pallas
    # kernels have the fused megatile path, so the search tunes
    # tiles_per_step; dtype="bfloat16" means both precisions are searched
    # and the winner is picked per matrix.
    if target.backend == "pallas" and cfg.tiles_per_step_choices is None:
        cfg = dataclasses.replace(cfg, tiles_per_step_choices=(1, 4, 8))
    if target.dtype == "bfloat16" and cfg.dtype_choices is None:
        cfg = dataclasses.replace(cfg,
                                  dtype_choices=("float32", "bfloat16"))
    return cfg


def _plan_from_program(prog, graph: Optional[OperatorGraph],
                       target: Target, search_result=None) -> SpmvPlan:
    graph_json = (None if graph is None
                  else json.dumps(_graph_to_jsonable(graph)))
    failure_counts = None
    if search_result is not None and getattr(search_result,
                                             "failure_counts", None):
        failure_counts = tuple(sorted(search_result.failure_counts.items()))
    plan = SpmvPlan(fmt=dict(prog.fmt), spec_json=json.dumps(prog.spec),
                    graph_json=graph_json, target=target,
                    search_gflops=(search_result.gflops
                                   if search_result else None),
                    failure_counts=failure_counts,
                    search_result=search_result)
    return plan


def compile(matrix: SparseMatrix, target: Optional[Target] = None,
            budget=None, *, graph: Optional[OperatorGraph] = None,
            strategy=None, warm_start=None, deadline_s: Optional[float] = None,
            cache: Optional[ProgramCache] = None,
            store: Optional["PlanStore"] = None
            ) -> Union[SpmvPlan, ShardedSpmvPlan]:
    """Matrix in, machine-designed program artifact out (paper §III).

    * ``target`` — where the plan runs (defaults to ``Target()``: jax
      backend, single device).
    * ``budget`` — search budget: a ``SearchConfig``, a number of seconds,
      or None for the default budget. With ``target.mesh`` set and
      ``budget=None``, shards take the search-free heuristic design.
    * ``graph`` — skip the search entirely and design with this Operator
      Graph (sharded targets apply it per shard).
    * ``strategy`` — the search policy walking the design space: a
      ``repro.design.SearchStrategy`` instance/class or a registered name
      ("anneal" | "grid" | "cost_model" | "learned" | "portfolio").
      Store-aware strategies get ``bind_store(store)`` called before the
      search, which is how "portfolio" reaches reuse suggestions and the
      trained corpus model. None = ``AnnealStrategy``, the
      historical SA walk (behavioral parity). Sharded targets pass the
      strategy to every per-shard search (no-op with ``budget=None``,
      where shards take the search-free heuristic design).
    * ``warm_start`` — optional iterable of ``OperatorGraph`` objects timed
      before the strategy's walk (dense targets only; per-shard searches
      ignore it). With a ``store`` given and no explicit warm start,
      ``store.suggest(matrix)`` (statistics-keyed nearest stored plan)
      seeds the search automatically.
    * ``deadline_s`` — hard wall-clock budget for the whole compile
      (dense searched targets). The search's ``max_seconds`` is clamped
      to it, the seed pass loses its 2x extension, and every candidate
      runs under a per-candidate deadline derived from the time left —
      ``compile`` always returns the best plan found so far (at worst
      the baseline jax-backend source-format program, never an error,
      as long as the matrix itself is designable).
    * ``cache`` — a ``ProgramCache`` memoising raw search results (keyed
      by matrix, budget AND strategy).
    * ``store`` — a :class:`PlanStore`; a prior plan for the same
      (matrix, budget, target) is loaded instead of recompiled, and new
      plans are saved. Store hits carry no ``search_result`` (the full
      ``SearchResult`` is process-ephemeral and not serialized) —
      ``search_gflops`` survives the round trip.
    """
    target = target or Target()
    if strategy is not None:
        # normalize once so store keys see the *bound* strategy: a
        # store-aware strategy ("portfolio", "learned") keys on its model
        # fingerprint, and get/put must agree on it
        from repro.design.strategies import make_strategy
        strategy = make_strategy(strategy)
        if store is not None and hasattr(strategy, "bind_store"):
            strategy.bind_store(store)
    if store is not None:
        hit = store.get(matrix, target, budget, graph, strategy)
        if hit is not None:
            return hit
        if warm_start is None and graph is None and target.mesh is None:
            # statistics-keyed warm start from the nearest stored plan
            # (dense targets only: per-shard warm-start is future work)
            suggested = store.suggest(matrix)
            warm_start = (suggested,) if suggested is not None else None

    if target.mesh is None:
        if graph is not None:
            meta = run_graph(matrix, graph)
            # Target.dtype overrides the storage dtype for fixed-graph
            # compiles (searched compiles pick it via SET_RESOURCES)
            prog = build_program(meta, backend=target.backend,
                                 interpret=target.interpret, jit=False,
                                 storage_dtype=(target.dtype
                                                if target.dtype != "float32"
                                                else None))
            plan = _plan_from_program(prog, graph, target)
        else:
            cfg = _as_search_config(budget, target)
            if deadline_s is not None:
                # the whole search — seed pass included — must fit inside
                # the caller's wall-clock budget; candidates inherit a
                # per-candidate deadline from the time remaining
                cfg = dataclasses.replace(
                    cfg, max_seconds=min(cfg.max_seconds, float(deadline_s)),
                    hard_deadline=True)
            res = run_search(matrix, cfg, cache=cache, strategy=strategy,
                             warm_start=warm_start)
            plan = _plan_from_program(res.best_program, res.best_graph,
                                      target, search_result=res)
    else:
        from repro.dist.search import ShardedSearchConfig, dist_search
        from repro.dist.spmv import shard_map_spmv
        search_result = None
        if graph is not None:
            sprog = shard_map_spmv(matrix, target.mesh,
                                   axis_name=target.axis_name,
                                   mode=target.partition,
                                   balance=target.balance,
                                   graph_for=lambda m: graph,
                                   backend=target.backend,
                                   interpret=target.interpret,
                                   storage_dtype=target.dtype)
        elif budget is None:
            sprog = shard_map_spmv(matrix, target.mesh,
                                   axis_name=target.axis_name,
                                   mode=target.partition,
                                   balance=target.balance,
                                   backend=target.backend,
                                   interpret=target.interpret,
                                   storage_dtype=target.dtype)
        else:
            if isinstance(budget, ShardedSearchConfig):
                # full per-shard control (min_nnz_for_search, seeds, ...);
                # the Target still decides placement and backend
                dcfg = dataclasses.replace(
                    budget, axis_name=target.axis_name,
                    mode=target.partition, balance=target.balance,
                    backend=target.backend, interpret=target.interpret)
                if strategy is not None:
                    dcfg = dataclasses.replace(dcfg, strategy=strategy)
            else:
                dcfg = ShardedSearchConfig(axis_name=target.axis_name,
                                           mode=target.partition,
                                           balance=target.balance,
                                           search=_as_search_config(
                                               budget, target),
                                           backend=target.backend,
                                           interpret=target.interpret,
                                           strategy=strategy)
            search_result = dist_search(matrix, target.mesh, dcfg,
                                        cache=cache)
            sprog = search_result.program
        plan = ShardedSpmvPlan.from_program(sprog, target,
                                            search_result=search_result)

    if store is not None:
        store.put(matrix, target, budget, graph, plan, strategy)
    return plan


# -------------------------------- PlanStore ---------------------------------

def _matrix_stats(matrix: SparseMatrix) -> list[float]:
    """Statistics key for nearest-plan lookup: size + row-length shape.

    The features are the ones the §VI-B pruning rules key on: row count,
    mean/std of nnz per row, and the row-length coefficient of variation
    (irregularity). Two matrices close in this space tend to get the same
    winning design, which is what makes the stored graph a useful warm
    start for *any* strategy."""
    lengths = np.bincount(np.asarray(matrix.rows, np.int64),
                          minlength=matrix.n_rows).astype(np.float64)
    mean = float(lengths.mean()) if lengths.size else 0.0
    std = float(lengths.std()) if lengths.size else 0.0
    cv = std / mean if mean > 0 else 0.0
    return [float(matrix.n_rows), mean, std, cv]


def _stats_distance(a, b) -> float:
    """Scale-normalized distance: log-scale for counts, linear for CV."""
    d = 0.0
    d += (np.log10(1.0 + a[0]) - np.log10(1.0 + b[0])) ** 2
    d += (np.log10(1.0 + a[1]) - np.log10(1.0 + b[1])) ** 2
    d += (np.log10(1.0 + a[2]) - np.log10(1.0 + b[2])) ** 2
    d += (a[3] - b[3]) ** 2
    return float(np.sqrt(d))


class PlanWatch:
    """Poll one PlanStore entry for changes (the serving hot-swap hook).

    Created by :meth:`PlanStore.watch`. ``poll()`` stats the entry's file
    and returns a freshly loaded plan iff its (mtime_ns, size) stamp
    changed since the last observation — None otherwise. A poll is one
    ``stat`` call, cheap enough for serving engines to issue between
    every decode step; a half-written or corrupt entry is skipped (the
    old plan keeps serving) and retried on the next poll.
    """

    def __init__(self, store: "PlanStore", key: str, mesh=None):
        self.store = store
        self.key = key
        self.mesh = mesh
        self._seen = self._stamp()

    @property
    def path(self) -> Path:
        return self.store._path(self.key)

    def _stamp(self):
        try:
            st = self.path.stat()
            return (st.st_mtime_ns, st.st_size)
        except OSError:
            return None

    def poll(self):
        stamp = self._stamp()
        if stamp is None or stamp == self._seen:
            return None
        try:
            plan = load_plan(self.path, mesh=self.mesh)
        except Exception:
            return None   # mid-write or corrupt: retry on the next poll
        self._seen = stamp
        return plan


class PlanStore:
    """A directory of saved plans keyed by (matrix, budget/graph, strategy,
    Target).

    Supersedes ``ProgramCache``'s replay-only disk entries: where the
    program cache stores the winning *graph* and re-runs the Designer +
    kernel builder on a hit, the plan store round-trips the full artifact
    (spec + format arrays) — a hit is a load, bit-identical to the saved
    plan, with no matrix or Designer replay required.

    Beyond exact hits, the store answers :meth:`suggest` — a statistics-
    keyed nearest-plan lookup (first step of the ROADMAP "autotune cache
    keyed on matrix statistics" item): each ``put`` writes a small
    ``.stats.json`` sidecar (matrix row statistics + winning graph), and
    ``suggest(matrix)`` returns the stored winning ``OperatorGraph`` of
    the statistically closest plan, which ``repro.compile`` uses to
    warm-start the search.
    """

    def __init__(self, cache_dir):
        self.cache_dir = Path(cache_dir)
        self.hits = 0
        self.misses = 0
        # suggest() sidecar index: path -> ((mtime_ns, size), payload).
        # payload is None for corrupt sidecars (negative cache). The whole
        # index is revalidated only when the *directory* mtime moves —
        # sidecars are written atomically (os.replace into the directory),
        # so every add/replace/remove bumps it.
        self._sidecars: dict[Path, tuple[tuple[int, int], Optional[dict]]] = {}
        self._sidecar_dir_stamp: Optional[int] = None

    @staticmethod
    def key(matrix: SparseMatrix, target: Target, budget=None,
            graph: Optional[OperatorGraph] = None, strategy=None) -> str:
        from repro.design.strategies import make_strategy
        mfp = ProgramCache.matrix_fingerprint(matrix)
        if graph is not None:
            bkey = "g" + hashlib.sha1(json.dumps(
                _graph_to_jsonable(graph)).encode()).hexdigest()[:8]
        elif budget is None:
            bkey = "default"
        elif dataclasses.is_dataclass(budget):   # SearchConfig / sharded cfg
            blob = json.dumps(dataclasses.asdict(budget), sort_keys=True,
                              default=str)
            bkey = hashlib.sha1(blob.encode()).hexdigest()[:8]
        else:
            bkey = f"s{float(budget):g}"
        if graph is None:
            # the strategy identity is part of the key (same collision
            # rule as ProgramCache): a grid-searched plan must not serve
            # an anneal-searched request for the same matrix/budget
            bkey += "-" + hashlib.sha1(
                make_strategy(strategy).key().encode()).hexdigest()[:8]
        return f"{mfp}-{bkey}-{target.key()}"

    def _path(self, key: str) -> Path:
        return self.cache_dir / f"{key}.plan.npz"

    def get(self, matrix, target, budget=None, graph=None, strategy=None):
        path = self._path(self.key(matrix, target, budget, graph, strategy))
        if not path.exists():
            self.misses += 1
            return None
        try:
            plan = load_plan(path, mesh=target.mesh)
        except Exception as e:  # truncated/corrupt npz or checksum
            # mismatch (PlanIntegrityError): recompile, like ProgramCache,
            # instead of failing forever
            warnings.warn(f"plan store entry {path} unusable ({e!r}); "
                          "recompiling", RuntimeWarning)
            self.misses += 1
            return None
        self.hits += 1
        return plan

    def put(self, matrix, target, budget, graph, plan,
            strategy=None) -> None:
        self.cache_dir.mkdir(parents=True, exist_ok=True)
        key = self.key(matrix, target, budget, graph, strategy)
        plan.save(self._path(key))
        graph_json = getattr(plan, "graph_json", None)
        if graph_json is not None:
            from repro.corpus.features import matrix_features
            sidecar = {"stats": _matrix_stats(matrix),
                       "features": matrix_features(matrix).tolist(),
                       "graph": json.loads(graph_json),
                       "gflops": getattr(plan, "search_gflops", None)}
            _atomic_write_text(self.cache_dir / f"{key}.stats.json",
                               json.dumps(sidecar))

    def verify(self) -> dict:
        """Integrity sweep over every stored entry.

        Loads each ``*.plan.npz`` (no mesh attached — sharded geometry
        checks are deferred to serving) and returns
        ``{"ok": [keys], "corrupt": [(key, reason)]}``. Truncated files,
        bad zip containers and checksum mismatches all land in
        ``corrupt``; nothing is modified — use :meth:`repair` to
        quarantine them."""
        ok, corrupt = [], []
        if self.cache_dir.is_dir():
            for path in sorted(self.cache_dir.glob("*.plan.npz")):
                key = path.name[:-len(".plan.npz")]
                try:
                    load_plan(path)
                except Exception as e:
                    corrupt.append((key, repr(e)))
                else:
                    ok.append(key)
        return {"ok": ok, "corrupt": corrupt}

    def repair(self) -> list[str]:
        """Quarantine every corrupt entry found by :meth:`verify`.

        Corrupt ``*.plan.npz`` files (and their ``.stats.json`` sidecars,
        so ``suggest`` stops reading them) are moved into a
        ``quarantine/`` subdirectory — kept for post-mortem, never served
        again; the next ``get`` for that key recompiles. Returns the
        quarantined keys."""
        quarantined = []
        qdir = self.cache_dir / "quarantine"
        for key, _reason in self.verify()["corrupt"]:
            qdir.mkdir(parents=True, exist_ok=True)
            for suffix in (".plan.npz", ".stats.json"):
                src = self.cache_dir / f"{key}{suffix}"
                if src.exists():
                    os.replace(src, qdir / src.name)
            quarantined.append(key)
        return quarantined

    def watch(self, matrix, target, budget=None, graph=None,
              strategy=None) -> PlanWatch:
        """A :class:`PlanWatch` on this (matrix, budget/graph, strategy,
        Target) key. The watch records the entry's current stamp at
        creation, so only *subsequent* puts (a better plan landing from
        an offline search, a re-tune) trigger a reload — serving engines
        poll it between steps for zero-downtime hot-swap."""
        return PlanWatch(self, self.key(matrix, target, budget, graph,
                                        strategy),
                         mesh=target.mesh)

    def _refresh_sidecars(self) -> None:
        """Revalidate the in-memory sidecar index, O(changed files).

        Cheap path: one ``stat`` of the directory; if its mtime_ns is
        unchanged since the last suggest(), nothing on disk was atomically
        added/replaced/removed and the index is served as-is. Otherwise
        files are re-statted and only entries whose (mtime_ns, size) stamp
        moved are re-parsed; corrupt files are negative-cached so a bad
        sidecar is parsed (and skipped) once, not per call."""
        try:
            dir_stamp = self.cache_dir.stat().st_mtime_ns
        except OSError:
            self._sidecars.clear()
            self._sidecar_dir_stamp = None
            return
        if dir_stamp == self._sidecar_dir_stamp:
            return
        seen = set()
        for path in self.cache_dir.glob("*.stats.json"):
            try:
                st = path.stat()
            except OSError:
                continue   # removed between glob and stat
            seen.add(path)
            stamp = (st.st_mtime_ns, st.st_size)
            cached = self._sidecars.get(path)
            if cached is not None and cached[0] == stamp:
                continue
            try:
                payload = json.loads(path.read_text())
                payload["stats"][0]   # shape check: stats must index
                payload["graph"]
            except (OSError, ValueError, KeyError, IndexError, TypeError):
                payload = None        # negative cache: skip until it changes
            self._sidecars[path] = (stamp, payload)
        for path in list(self._sidecars):
            if path not in seen:
                del self._sidecars[path]
        self._sidecar_dir_stamp = dir_stamp

    def suggest(self, matrix: SparseMatrix, max_distance: float = 1.0,
                with_distance: bool = False):
        """Winning graph of the statistically nearest stored plan.

        Returns None when the store is empty or nothing is within
        ``max_distance`` in normalized statistics space (a candidate at
        exactly ``max_distance`` is accepted). The returned graph
        warm-starts any strategy (``repro.compile(..., warm_start=[g])``);
        it is *timed like any other candidate*, so a bad suggestion costs
        one evaluation, never correctness.

        With ``with_distance=True`` returns ``(graph_or_None, distance)``
        (``math.inf`` when nothing matched) — the portfolio strategy
        gates its refinement phase on this confidence signal.

        Sidecars are indexed in memory and revalidated by directory
        mtime, so corpus-scale stores (hundreds of entries) pay parsing
        only for files that actually changed."""
        if not self.cache_dir.is_dir():
            return (None, math.inf) if with_distance else None
        self._refresh_sidecars()
        want = _matrix_stats(matrix)
        best_d, best_graph = math.inf, None
        for _stamp, payload in self._sidecars.values():
            if payload is None:
                continue
            try:
                d = _stats_distance(want, payload["stats"])
            except (ValueError, KeyError, IndexError, TypeError):
                continue
            if d < best_d:
                best_d, best_graph = d, payload["graph"]
        if best_graph is None or best_d > max_distance:
            return (None, math.inf) if with_distance else None
        graph = _graph_from_jsonable(best_graph)
        return (graph, best_d) if with_distance else graph
